"""Tracked perf harness for the vectorized mapping hot path.

Measures TOFA placement latency (cold engine and warm cache) and hop-bytes
quality at n in {64, 256, 512, 1024} processes on 8^3 / 16^3 tori and a
3-level fat-tree, and — for the small cases where it is affordable —
re-runs the same pipeline through the retained scalar-loop kernels
(``repro.core.mapping.use_reference_impl``) to record the speedup and check
the vectorized placement is hop-bytes equal-or-better on every case.

The numbers land in ``benchmarks/BENCH_mapping.json`` as a *trajectory*:
each invocation with ``--write`` appends one labelled point, so future PRs
can regress against the recorded history.

    PYTHONPATH=src python -m benchmarks.refine_scale           # measure only
    PYTHONPATH=src python -m benchmarks.refine_scale --write   # + append a
        trajectory point to benchmarks/BENCH_mapping.json
    PYTHONPATH=src python -m benchmarks.refine_scale --fast    # CI smoke:
        re-times the warm n=256 / 8x8x8 case and exits 1 if it is more
        than 2x slower than the committed baseline trajectory point
        (after normalising by a machine-speed calibration, so slow or
        noisy CI runners do not fail the gate spuriously).

Backend axis (the jax placement backend of ``repro.core.backend``):

    ... refine_scale --backend jax            # run the matrix under jax
    ... refine_scale --backend-bench          # numpy-vs-jax kernel duel on
        the ``_pairwise_refine`` candidate stacks (interleaved, warm-jit);
        exits 1 unless jax beats numpy at n >= 1024.  --write appends the
        measured speedups to benchmarks/BENCH_backend.json; --fast trims
        repeats for CI.
    ... refine_scale --backend-bench --devices 8   # adds the sharded duel:
        single-device vmap vs shard_map over 8 (virtual) devices on a
        portfolio-shaped candidate stack; exits 1 unless the sharded
        dispatch wins and stays bit-identical.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

import numpy as np

from repro.core import backend as core_backend
from repro.core import mapping
from repro.core.engine import PlacementEngine, PlacementRequest
from repro.core.fattree import FatTreeTopology
from repro.core.topology import TorusTopology
from repro.workloads.patterns import npb_dt_like

BENCH_PATH = Path(__file__).resolve().parent / "BENCH_mapping.json"
BACKEND_BENCH_PATH = Path(__file__).resolve().parent / "BENCH_backend.json"
SCHEMA_VERSION = 1
# the CI gate case (acceptance anchor): warm-cache tofa at n=256 on 8x8x8
GATE_CASE = "torus-8x8x8/n256/healthy"
GATE_FACTOR = 2.0
# how far machine-speed normalisation may stretch/shrink the gate limit
CALIBRATION_CLAMP = 4.0


def _calibrate(repeats: int = 5) -> float:
    """Seconds for a fixed NumPy workload shaped like the mapper hot path
    (gathers + matvecs) — a machine-speed yardstick recorded next to the
    baseline so the CI gate compares like with like across runners."""
    rng = np.random.default_rng(0)
    A = rng.random((512, 512))
    idx = rng.integers(0, 512, 512)
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(8):
            M = A[np.ix_(idx, idx)]
            (M @ A[0]).sum()
            np.argsort(M.sum(axis=1))
        best = min(best, time.perf_counter() - t0)
    return float(best)


def _topologies() -> dict:
    return {
        "torus-8x8x8": lambda: TorusTopology((8, 8, 8)),
        "torus-16x16x16": lambda: TorusTopology((16, 16, 16)),
        "fattree-k16": lambda: FatTreeTopology(16),
    }


def _case_list(fast: bool) -> list[dict]:
    """(topology, n_procs, n_faulty, run_reference) measurement matrix."""
    if fast:
        return [dict(topo="torus-8x8x8", n=256, n_faulty=0, reference=False)]
    cases = [
        dict(topo="torus-8x8x8", n=64, n_faulty=0, reference=True),
        dict(topo="torus-8x8x8", n=64, n_faulty=16, reference=True),
        dict(topo="torus-8x8x8", n=256, n_faulty=0, reference=True),
        dict(topo="torus-8x8x8", n=256, n_faulty=16, reference=True),
        dict(topo="fattree-k16", n=64, n_faulty=0, reference=True),
        dict(topo="fattree-k16", n=256, n_faulty=32, reference=True),
        dict(topo="fattree-k16", n=512, n_faulty=0, reference=False),
        dict(topo="fattree-k16", n=1024, n_faulty=0, reference=False),
        dict(topo="torus-16x16x16", n=512, n_faulty=0, reference=False),
        dict(topo="torus-16x16x16", n=1024, n_faulty=0, reference=False),
    ]
    return cases


def _case_name(topo: str, n: int, n_faulty: int) -> str:
    return f"{topo}/n{n}/" + ("healthy" if n_faulty == 0 else f"faulty{n_faulty}")


def _request(topo_name: str, n: int, n_faulty: int) -> PlacementRequest:
    topo = _topologies()[topo_name]()
    wl = npb_dt_like(n, seed=3)
    p_f = None
    if n_faulty:
        p_f = np.zeros(topo.n_nodes)
        bad = np.random.default_rng(7).choice(topo.n_nodes, n_faulty,
                                              replace=False)
        p_f[bad] = 0.02
    return PlacementRequest(comm=wl.comm, topology=topo, p_f=p_f)


def _time_place(engine: PlacementEngine, req: PlacementRequest,
                repeats: int = 3) -> tuple[float, float]:
    """(best-of-N wall seconds, hop_bytes) for repeated warm placements.

    Min, not median: the gate compares absolute wall time across machines,
    and min-of-N is the standard way to strip scheduler/load noise from a
    deterministic computation's timing.
    """
    times, hb = [], None
    for _ in range(repeats):
        t0 = time.perf_counter()
        plan = engine.place(req, policy="tofa", rng=np.random.default_rng(0))
        times.append(time.perf_counter() - t0)
        hb = plan.hop_bytes
    return float(np.min(times)), float(hb)


def _measure_case(case: dict, csv=print) -> dict:
    name = _case_name(case["topo"], case["n"], case["n_faulty"])
    req = _request(case["topo"], case["n"], case["n_faulty"])

    # cold: fresh engine — pays hop-matrix (+ Eq. 1 weights) derivation
    t0 = time.perf_counter()
    PlacementEngine().place(req, policy="tofa", rng=np.random.default_rng(0))
    cold_s = time.perf_counter() - t0
    # warm: shared engine — matrices and TOFA candidates cached
    engine = PlacementEngine()
    engine.place(req, policy="tofa", rng=np.random.default_rng(0))
    warm_s, hop_b = _time_place(engine, req,
                                repeats=case.get("smoke_repeats", 3))

    row = {
        "case": name,
        "topology": case["topo"],
        "n_procs": case["n"],
        "n_nodes": req.topology.n_nodes,
        "n_faulty": case["n_faulty"],
        "policy": "tofa",
        "cold_s": round(cold_s, 6),
        "warm_s": round(warm_s, 6),
        "hop_bytes": hop_b,
        "reference_warm_s": None,
        "reference_hop_bytes": None,
        "speedup_vs_reference": None,
    }
    csv(f"refine_scale,{name},cold,{cold_s*1e3:.2f},ms_place_time")
    csv(f"refine_scale,{name},warm,{warm_s*1e3:.2f},ms_place_time,"
        f"hop_bytes={hop_b:.4e}")

    if case["reference"]:
        with mapping.use_reference_impl():
            ref_engine = PlacementEngine()
            ref_engine.place(req, policy="tofa", rng=np.random.default_rng(0))
            ref_s, ref_hb = _time_place(ref_engine, req, repeats=1)
        row["reference_warm_s"] = round(ref_s, 6)
        row["reference_hop_bytes"] = ref_hb
        row["speedup_vs_reference"] = round(ref_s / warm_s, 2) if warm_s else None
        ok = hop_b <= ref_hb * (1 + 1e-9)
        csv(f"refine_scale,{name},speedup_vs_reference,"
            f"{row['speedup_vs_reference']},x,"
            f"hop_bytes_equal_or_better={ok}")
        if not ok:
            raise AssertionError(
                f"{name}: vectorized hop_bytes {hop_b:.6e} worse than "
                f"reference {ref_hb:.6e}")
    return row


def _load_baseline() -> dict | None:
    if not BENCH_PATH.exists():
        return None
    with open(BENCH_PATH) as f:
        return json.load(f)


def _smoke(csv=print) -> int:
    """CI gate: warm n=256 / 8x8x8 vs the committed trajectory baseline."""
    baseline = _load_baseline()
    if baseline is None or not baseline.get("trajectory"):
        csv(f"refine_scale,smoke,SKIP,no committed {BENCH_PATH.name} baseline")
        return 0
    point = baseline["trajectory"][-1]
    base = next((c for c in point["cases"] if c["case"] == GATE_CASE), None)
    if base is None:
        csv(f"refine_scale,smoke,SKIP,baseline lacks case {GATE_CASE}")
        return 0

    case = dict(_case_list(fast=True)[0], smoke_repeats=5)
    row = _measure_case(case, csv=csv)
    # normalise for machine speed: the committed baseline was measured on a
    # different machine; scale its warm_s by the calibration ratio (clamped)
    scale = 1.0
    base_cal = point.get("calibration_s")
    if base_cal:
        scale = _calibrate() / base_cal
        scale = min(max(scale, 1.0 / CALIBRATION_CLAMP), CALIBRATION_CLAMP)
    limit = base["warm_s"] * scale * GATE_FACTOR
    csv(f"refine_scale,smoke,warm_s,{row['warm_s']:.4f},s,"
        f"baseline={base['warm_s']:.4f},machine_scale={scale:.2f},"
        f"limit={limit:.4f}")
    if row["hop_bytes"] > base["hop_bytes"] * (1 + 1e-6):
        csv(f"refine_scale,smoke,WARN,hop_bytes drifted "
            f"{row['hop_bytes']:.6e} vs baseline {base['hop_bytes']:.6e}")
    if row["warm_s"] > limit:
        csv(f"refine_scale,smoke,FAIL,warm placement {row['warm_s']:.4f}s "
            f"> {GATE_FACTOR}x machine-normalised baseline (limit {limit:.4f}s)")
        return 1
    csv("refine_scale,smoke,PASS,within regression budget")
    return 0


def _refine_stack(topo_dims: tuple[int, ...], n: int, n_cands: int
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(G, D, candidate stack) shaped like TOFA's multi-candidate refine:
    the DRB + snake map candidates plus seeded restart permutations."""
    topo = TorusTopology(topo_dims)
    wl = npb_dt_like(n, seed=3)
    G = wl.comm.weights("volume")
    D = topo.hop_matrix()
    cands = mapping._map_candidates(G, np.arange(topo.n_nodes),
                                    topo.coords_array(), D,
                                    np.random.default_rng(0))
    rng = np.random.default_rng(1)
    while len(cands) < n_cands:
        cands.append(rng.permutation(topo.n_nodes)[:n])
    return G, D, np.stack(cands[:n_cands])


BACKEND_CASES = [
    # (name, torus dims, n procs, candidates, part of --fast, gated).
    # The x10/x16 stacks mirror TOFA's real candidate counts at that
    # scale: a healthy search refines 10 candidates (windows + ball, two
    # map candidates each), a faulty search up to 16 (extra far-seeded
    # balls) — the shapes the vmapped dispatch amortises across.
    ("refine/torus-8x8x8/n256x10", (8, 8, 8), 256, 10, False, False),
    ("refine/torus-16x16x16/n1024x1", (16, 16, 16), 1024, 1, False, False),
    ("refine/torus-16x16x16/n1024x10", (16, 16, 16), 1024, 10, False, True),
    ("refine/torus-16x16x16/n1024x16", (16, 16, 16), 1024, 16, True, True),
]
BACKEND_GATE_MIN_N = 1024
# the sharded duel case: TOFA's biggest candidate stack on the 4096-node
# torus, refined through the implicit-coordinate path
SHARDED_CASE = ("shard/torus-16x16x16/n1024x16", (16, 16, 16), 1024, 16)


def _sharded_duel(csv, *, n_dev: int, repeats: int) -> tuple[dict, int]:
    """Single-device vmap vs sharded candidate-stack refine.

    The stack is portfolio-shaped: most candidates are near-converged
    (TOFA's multilevel/greedy seeds) and a few are raw restarts, spread
    across shards.  That heterogeneity is where sharding earns its
    speedup on any device count — each shard's ``lax.while_loop`` stops
    when *its* candidates converge, while the single-device vmap runs
    every lane until the slowest candidate in the whole stack does.
    Placements must stay bit-identical between the two dispatches.
    """
    from repro.core import mapping_jax
    name, dims, n, n_cands = SHARDED_CASE
    topo = TorusTopology(dims)
    Dl = topo.lazy_distance()
    wl = npb_dt_like(n, seed=3)
    G = wl.comm.weights("volume")
    rng = np.random.default_rng(1)
    n_raw = min(4, max(1, n_cands // 4))
    P = np.stack([rng.permutation(topo.n_nodes)[:n]
                  for _ in range(n_cands)])
    with core_backend.use("jax", devices=1):
        # refine the seed candidates to a swap fixed point so their lanes
        # converge in a pass or two when re-refined inside the duel
        seeds = P[:n_cands - n_raw]
        for _ in range(6):
            nxt = mapping_jax.refine_many(G, Dl, seeds)
            done = np.array_equal(nxt, seeds)
            seeds = nxt
            if done:
                break
    stack = np.concatenate([seeds, P[n_cands - n_raw:]])
    # interleave the raw candidates so they land in different shards
    order = np.argsort(np.r_[
        np.setdiff1d(np.arange(n_cands),
                     np.arange(n_raw) * (n_cands // n_raw)),
        np.arange(n_raw) * (n_cands // n_raw)], kind="stable")
    stack = stack[order]

    with core_backend.use("jax", devices=1):
        R_single = mapping_jax.refine_many(G, Dl, stack)   # compile (cold)
    with core_backend.use("jax"):
        R_shard = mapping_jax.refine_many(G, Dl, stack)
    t_single, t_shard = [], []
    for _ in range(repeats):
        with core_backend.use("jax", devices=1):
            t0 = time.perf_counter()
            mapping_jax.refine_many(G, Dl, stack)
            t_single.append(time.perf_counter() - t0)
        with core_backend.use("jax"):
            t0 = time.perf_counter()
            mapping_jax.refine_many(G, Dl, stack)
            t_shard.append(time.perf_counter() - t0)
    identical = bool(np.array_equal(R_single, R_shard))
    speedup = min(t_single) / min(t_shard)
    row = {
        "case": name, "n_procs": n, "n_candidates": n_cands,
        "n_nodes": int(np.prod(dims)), "devices": int(n_dev),
        "single_warm_s": round(min(t_single), 6),
        "sharded_warm_s": round(min(t_shard), 6),
        "sharded_speedup": round(speedup, 2),
        "placements_identical": identical,
    }
    csv(f"backend_bench,{name},sharded_speedup,{speedup:.2f},x,"
        f"devices={n_dev},single={min(t_single)*1e3:.0f}ms,"
        f"sharded={min(t_shard)*1e3:.0f}ms,identical={identical}")
    rc = 0
    if not identical:
        csv(f"backend_bench,{name},FAIL,sharded placements differ from "
            f"single-device vmap")
        rc = 1
    if speedup <= 1.0:
        csv(f"backend_bench,{name},FAIL,sharded refine slower than "
            f"single-device vmap on {n_dev} devices")
        rc = 1
    return row, rc


def backend_bench(csv=print, write: bool = False, fast: bool = False,
                  label: str | None = None) -> int:
    """NumPy-vs-jax duel on the ``_pairwise_refine`` hot kernel.

    Measures warm-jit (first jax call compiles and is discarded),
    interleaves the two backends best-of-N so machine-load drift hits
    both sides equally, asserts bit-identical placements and
    equal-or-better hop-bytes, and gates: jax must beat numpy on every
    case with n >= 1024.  The acceptance anchor is the n=1024 candidate
    stack on the 4096-node torus — the shape TOFA's vmapped
    multi-candidate search dispatches.
    """
    if not core_backend.has_jax():
        csv("backend_bench,SKIP,jax not installed")
        return 0
    repeats = 2 if fast else 3
    rows = []
    rc = 0
    cases = [c for c in BACKEND_CASES if c[4]] if fast else BACKEND_CASES
    for name, dims, n, n_cands, _in_fast, gated in cases:
        G, D, P = _refine_stack(dims, n, n_cands)
        with core_backend.use("jax"):
            R_jax = mapping.refine_batch(G, D, P)      # compile (cold)
        R_np = mapping.refine_batch(G, D, P)
        t_np, t_jax = [], []
        for _ in range(repeats):
            t0 = time.perf_counter()
            mapping.refine_batch(G, D, P)
            t_np.append(time.perf_counter() - t0)
            with core_backend.use("jax"):
                t0 = time.perf_counter()
                mapping.refine_batch(G, D, P)
                t_jax.append(time.perf_counter() - t0)
        hb_np = mapping.hop_bytes_batch(G, D, R_np)
        hb_jax = mapping.hop_bytes_batch(G, D, R_jax)
        identical = bool(np.array_equal(R_np, R_jax))
        hb_ok = bool((hb_jax <= hb_np * (1 + 1e-9)).all())
        speedup = min(t_np) / min(t_jax)
        rows.append({
            "case": name, "n_procs": n, "n_candidates": n_cands,
            "n_nodes": int(np.prod(dims)),
            "numpy_warm_s": round(min(t_np), 6),
            "jax_warm_s": round(min(t_jax), 6),
            "speedup": round(speedup, 2),
            "placements_identical": identical,
            "hop_bytes_equal_or_better": hb_ok,
        })
        csv(f"backend_bench,{name},speedup,{speedup:.2f},x,"
            f"numpy={min(t_np)*1e3:.0f}ms,jax={min(t_jax)*1e3:.0f}ms,"
            f"identical={identical},hop_bytes_ok={hb_ok}")
        if not identical or not hb_ok:
            csv(f"backend_bench,{name},FAIL,parity/quality violated")
            rc = 1
        if gated and n >= BACKEND_GATE_MIN_N and speedup <= 1.0:
            csv(f"backend_bench,{name},FAIL,jax slower than numpy at "
                f"n>={BACKEND_GATE_MIN_N}")
            rc = 1
    n_dev = core_backend.get_backend("jax").device_count
    if n_dev > 1:
        shard_row, shard_rc = _sharded_duel(csv, n_dev=n_dev,
                                            repeats=repeats)
        rows.append(shard_row)
        rc |= shard_rc
    else:
        csv("backend_bench,sharded,SKIP,single local device (set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N or "
            "--devices N)")
    if write:
        doc = {"schema": SCHEMA_VERSION,
               "description": (
                   "Warm-jit jax vs numpy on the _pairwise_refine hot "
                   "kernel (candidate-stack shapes). Appended by "
                   "benchmarks/refine_scale.py --backend-bench --write; "
                   "CI gate: jax beats numpy on gated n>=1024 cases."),
               "gate": {"min_n": BACKEND_GATE_MIN_N, "factor": 1.0},
               "trajectory": []}
        if BACKEND_BENCH_PATH.exists():
            doc = json.loads(BACKEND_BENCH_PATH.read_text())
        doc["trajectory"].append({"label": label or "unlabelled",
                                  "calibration_s": round(_calibrate(), 6),
                                  "cases": rows})
        BACKEND_BENCH_PATH.write_text(json.dumps(doc, indent=2) + "\n")
        csv(f"backend_bench,write,{BACKEND_BENCH_PATH.name},"
            f"trajectory_points={len(doc['trajectory'])}")
    return rc


def run(csv=print, write: bool = False, label: str | None = None) -> dict:
    """Measure the full matrix; optionally append a trajectory point."""
    fast = bool(os.environ.get("FAST"))
    rows = [_measure_case(c, csv=csv) for c in _case_list(fast=fast)]
    point = {
        "label": label or "unlabelled",
        "calibration_s": round(_calibrate(), 6),
        "cases": rows,
    }
    if write:
        doc = _load_baseline() or {
            "schema": SCHEMA_VERSION,
            "description": (
                "Placement-latency / hop-bytes trajectory of the mapping hot "
                "path. Appended by benchmarks/refine_scale.py --write; the "
                "CI smoke gate (--fast) compares against the last point."),
            "gate": {"case": GATE_CASE, "factor": GATE_FACTOR},
            "trajectory": [],
        }
        doc["trajectory"].append(point)
        with open(BENCH_PATH, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        csv(f"refine_scale,write,{BENCH_PATH.name},"
            f"trajectory_points={len(doc['trajectory'])}")
    return point


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: time the gate case against the committed "
                         "baseline; exit 1 on >2x regression")
    ap.add_argument("--write", action="store_true",
                    help="append this run as a new trajectory point")
    ap.add_argument("--label", default=None,
                    help="trajectory point label (e.g. the PR name)")
    ap.add_argument("--backend", choices=("numpy", "jax"), default="numpy",
                    help="array backend the measured pipeline runs under")
    ap.add_argument("--backend-bench", action="store_true",
                    help="numpy-vs-jax duel on the refine kernel; exits 1 "
                         "unless jax beats numpy at n >= 1024 (with --write, "
                         "appends to BENCH_backend.json)")
    ap.add_argument("--devices", type=int, default=None,
                    help="force N virtual host devices for the sharded "
                         "refine duel (sets XLA_FLAGS "
                         "--xla_force_host_platform_device_count before "
                         "jax initialises; CPU-only convenience)")
    args = ap.parse_args()
    if args.devices and args.devices > 1:
        if "jax" in sys.modules:
            csv_err = ("refine_scale,devices,WARN,jax already imported; "
                       "--devices has no effect (set XLA_FLAGS in the "
                       "environment instead)")
            print(csv_err)
        else:
            flag = f"--xla_force_host_platform_device_count={args.devices}"
            os.environ["XLA_FLAGS"] = \
                (os.environ.get("XLA_FLAGS", "") + " " + flag).strip()
    if args.backend_bench:
        return backend_bench(write=args.write, fast=args.fast,
                             label=args.label)
    with core_backend.use(args.backend):
        if args.fast:
            return _smoke()
        run(write=args.write, label=args.label)
    return 0


if __name__ == "__main__":
    sys.exit(main())
