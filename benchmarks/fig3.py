"""E1 / paper Fig. 3 — placement quality without failures.

Compares {default-slurm(linear), random, greedy, scotch-analogue(topo)} on
NPB-DT-85 (Fig. 3a: completion time) and LAMMPS {32,64,128,256} (Fig. 3b:
timesteps/s proxy = 1/time) on the 8x8x8 torus with the paper's platform
constants.  Paper reference points: Scotch beats Default-slurm by 22% on
NPB-DT; wins at 32-128 ranks on LAMMPS and loses at 256 on 8x8x8.
"""
from __future__ import annotations

import numpy as np

from repro.core.topology import TorusTopology
from repro.core.engine import PlacementEngine, PlacementRequest
from repro.sim.jobsim import successful_runtime
from repro.sim.network import TorusNetwork
from repro.workloads.patterns import lammps_like, npb_dt_like

POLICIES = ("linear", "random", "greedy", "topo")


def run(csv=print) -> dict:
    topo = TorusTopology((8, 8, 8))
    net = TorusNetwork(topo)
    engine = PlacementEngine()
    out = {}

    wl = npb_dt_like(85)
    req = PlacementRequest(comm=wl.comm, topology=topo)
    times = {}
    for pol in POLICIES:
        res = engine.place(req, policy=pol, rng=np.random.default_rng(0))
        times[pol] = successful_runtime(wl, res.placement, net)
        csv(f"fig3a,npb_dt_85,{pol},{times[pol]*1e6:.0f},us_exec_time")
    imp = 1 - times["topo"] / times["linear"]
    csv(f"fig3a,npb_dt_85,topo_vs_linear,{imp:.3f},frac_improvement"
        f"  # paper: 0.22")
    out["npb_dt"] = {"times": times, "improvement": imp}

    for n in (32, 64, 128, 256):
        wl = lammps_like(n)
        req = PlacementRequest(comm=wl.comm, topology=topo)
        row = {}
        for pol in POLICIES:
            res = engine.place(req, policy=pol, rng=np.random.default_rng(0))
            t = successful_runtime(wl, res.placement, net)
            row[pol] = 1.0 / t  # timesteps/s proxy
            csv(f"fig3b,lammps_{n},{pol},{1.0/t:.3f},steps_per_s")
        out[f"lammps_{n}"] = row
    return out


if __name__ == "__main__":
    run()
