"""Belief-error sweep: placement quality as a function of outage-belief
quality (oracle -> learned -> adversarial -> static prior).

Runs the gated time-based clustersim presets through the Monte-Carlo
replica engine once per *belief mode* (same seeds across modes, so the
mode deltas are paired) and reports the belief-error -> completion-time
curve plus the paired delta CIs the gate consumes.  Modes, from zero
belief error upward (see ``repro.sim.scenarios._attach_belief``):

* ``oracle``       — ``FailureProcess.expected_p_f`` handed to placement
* ``learned``      — rack-pooled conjugate Bayes (``repro.beliefs``),
  pre-trained on a disjoint generated trace, updated online
* ``adversarial``  — the truth vector reversed in id order
* ``static``       — a uniform positive prior; under the Eq. 1
  ``p_f > 0`` pattern this is fault-*blind* placement, the baseline a
  learned belief must beat

**Checkpointing.**  The sweep defaults to ``checkpointing=False``:
with the presets' aggressive 0.05-interval checkpoints a node failure
costs ~the checkpoint interval, fault avoidance buys nothing, and the
belief axis is flat-to-inverted (avoiding flaky capacity scatters
placements for no offsetting gain — a real finding, measurable with
``--checkpointed``).  With restarts-from-scratch the curve is monotone
in belief error and the learned estimator's value shows:
on ``correlated-failures`` learned matches oracle and beats static with
a paired CI well above zero.

``--check`` gates three claims (CI method: BCa by default — small
paired deltas are where percentile coverage gets shaky):

1. learned beats static-prior on ``mean_completion`` with a paired
   delta CI excluding zero on >= 1 gated preset;
2. learned lands within ``ORACLE_GAP_MAX`` of the oracle's mean on
   every preset (bounded regret for using an estimate);
3. the belief tracker is cache-friendly: >= ``MIN_TRACKER_HIT_RATE``
   engine weight-cache hit rate (the BENCH_state floor) while the
   tracker ingests a full scenario's heartbeat/failure stream.

``--atol-sweep`` measures the ``Scheduler.p_f_atol`` sensitivity curve
(engine hit rate + epoch count vs. the interning tolerance, per belief
source) that informs the 0.15 default: placements are atol-invariant
(every Eq. 1 consumer reads only the ``p_f > 0`` pattern, and pattern
flips always mint epochs), so the default is simply the tightest value
at which raw monitor jitter mints no spurious epochs (full mode: 0.1
already drifts past the tolerance, 0.05 drops the hit rate to 0.893 —
below the committed 95% floor; a learned tracker stays at the floor at
every grid point).

    PYTHONPATH=src python -m benchmarks.belief_sweep --fast --check
    PYTHONPATH=src python -m benchmarks.belief_sweep --fast --write \
        --label pr10 --replicas 256
    PYTHONPATH=src python -m benchmarks.belief_sweep --fast --atol-sweep
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import time

import numpy as np

from repro.core.engine import PlacementEngine
from repro.sim.replicas import paired_compare, run_replicas
from repro.sim.scenarios import run_preset

BENCH_PATH = pathlib.Path(__file__).parent / "BENCH_beliefs.json"
MODES = ("oracle", "learned", "static", "adversarial")
SWEEP_PRESETS = ("correlated-failures", "cascading-racks",
                 "maintenance-burst")
# gate 2: mean_completion(learned) <= (1 + gap) * mean_completion(oracle)
# on every sweep preset.  Measured fast-mode gaps: correlated-failures
# ~1.00x, cascading-racks ~0.99x, maintenance-burst ~1.20x (the tight-
# capacity burst punishes any avoidance, estimated or perfect).
ORACLE_GAP_MAX = 0.30
# gate 3: the BENCH_state churn floor, now under tracker ingestion
MIN_TRACKER_HIT_RATE = 0.95
ATOL_GRID = (0.05, 0.10, 0.15, 0.25)

BELIEF_METRIC_KEYS = ("belief_err", "belief_pattern_precision",
                      "belief_pattern_recall")


def sweep(presets=SWEEP_PRESETS, modes=MODES, n_replicas: int = 24, *,
          fast: bool = False, base_seed: int = 0, B: int = 2000,
          alpha: float = 0.05, method: str = "bca",
          checkpointing: bool = False, executor: str = "serial",
          max_workers=None, csv=print) -> dict:
    """Replica sweep over (preset, belief_mode); same seeds per mode.

    Returns ``{preset: {"modes": {mode: row}, "comparisons": {...}}}``
    where each mode row carries the completion-time summary plus the
    mean belief error / pattern precision / pattern recall, and the
    comparisons are paired-delta CIs of learned-vs-static and
    learned-vs-oracle (positive delta == learned smaller == better).
    """
    results: dict = {}
    for preset in presets:
        t0 = time.perf_counter()
        sets = {}
        for mode in modes:
            sets[mode] = run_replicas(
                preset, n_replicas=n_replicas, base_seed=base_seed,
                policies=("tofa",), fast=fast, executor=executor,
                max_workers=max_workers, belief_mode=mode,
                checkpointing=checkpointing)
        rows = {}
        for mode in modes:
            rs = sets[mode]
            s = rs.summary("tofa", B=B, alpha=alpha, method=method)
            row = {"mean_completion": s.mean, "std": s.std,
                   "ci_low": s.ci_low, "ci_high": s.ci_high,
                   "n_replicas": s.n, "method": s.method}
            for key in BELIEF_METRIC_KEYS:
                vals = rs.metrics["tofa"].get(key)
                if vals is not None:
                    row[key] = float(vals.mean())
            rows[mode] = row
            csv(f"beliefs,{preset},{mode},{s.mean:.4f},s_mean_completion,"
                f"belief_err={row.get('belief_err', float('nan')):.5f},"
                f"ci=[{s.ci_low:.4f},{s.ci_high:.4f}]")
        comparisons = {}
        pairs = [("learned", "static"), ("learned", "oracle")]
        if "adversarial" in modes:
            pairs.append(("oracle", "adversarial"))
        for a, b in pairs:
            if a not in sets or b not in sets:
                continue
            cmp = paired_compare(
                sets[a].samples("tofa"), sets[b].samples("tofa"),
                metric="mean_completion", a=a, b=b, B=B, alpha=alpha,
                method=method)
            comparisons[f"{a}_vs_{b}"] = {
                "delta": cmp.delta, "delta_ci_low": cmp.delta_ci_low,
                "delta_ci_high": cmp.delta_ci_high,
                "win_rate": cmp.win_rate, "p_value": cmp.p_value,
                "n": cmp.n, "method": cmp.method}
            csv(f"beliefs,{preset},{a}_vs_{b},{cmp.delta:.4f},s_delta,"
                f"ci=[{cmp.delta_ci_low:.4f},{cmp.delta_ci_high:.4f}],"
                f"win_rate={cmp.win_rate:.3f},p={cmp.p_value:.4g}")
        results[preset] = {"modes": rows, "comparisons": comparisons}
        csv(f"beliefs,{preset},wall_time,{time.perf_counter() - t0:.1f},s")
    return results


def _tracker_serving_loop(fast: bool, seed: int, engine,
                          p_f_atol=None, source: str = "learned") -> dict:
    """The BENCH_state drain-sweep serving loop, belief source pluggable.

    ``source="learned"`` attaches a pre-trained :class:`BeliefTracker`
    (placement beliefs drift only with censored exposure — smooth and
    tiny per round); ``source="monitor"`` leaves the raw heartbeat
    estimate in charge (per-round sampling jitter, the regime the
    ``p_f_atol`` default must absorb).  Every round ingests one
    heartbeat and runs one placement; genuine node failures arrive
    every ``churn_every`` rounds.
    """
    from repro.beliefs import BeliefTracker, ExponentialBayes
    from repro.cluster.scheduler import Job, Scheduler
    from repro.core.topology import TorusTopology
    from repro.workloads.patterns import npb_dt_like

    dims = (4, 4, 4) if fast else (6, 6, 6)
    n_flaky = 12 if fast else 40
    rounds = 120 if fast else 250
    churn_every = 30 if fast else 25
    topo = TorusTopology(dims)
    rng0 = np.random.default_rng(seed * 401 + 19)
    flaky = rng0.choice(topo.n_nodes, n_flaky, replace=False)
    tracker = None
    if source == "learned":
        tracker = BeliefTracker(topo.n_nodes, ExponentialBayes())
        # pre-train: the flaky set has a real failure history, so its
        # posterior sits well above the emission floor for the whole
        # loop (10 completed 4s-lifetimes; healthy nodes keep only
        # prior mass, which the p_floor clamps to an exact-zero
        # pattern entry)
        for c in range(10):
            tracker.observe_failure(flaky, t=5.0 * c + 4.0)
            tracker.observe_repair(flaky, t=5.0 * c + 5.0)
        tracker.rebase(0.0)
    sch_kw = {} if p_f_atol is None else {"p_f_atol": p_f_atol}
    sch = Scheduler(topo, engine=engine, seed=seed, drain_threshold=0.6,
                    tracker=tracker, **sch_kw)
    truth = np.zeros(topo.n_nodes)
    truth[flaky] = 0.3
    sch.monitor.simulate_rounds(np.random.default_rng(seed ^ 0x5eed),
                                truth, 400)
    reply_rng = np.random.default_rng(seed * 77 + 5)
    wl = npb_dt_like(12 if fast else 16)
    healthy = np.setdiff1d(np.arange(topo.n_nodes), flaky)
    victims = np.empty(2 * min(len(flaky), len(healthy)), dtype=np.int64)
    victims[0::2] = flaky[:len(victims) // 2]
    victims[1::2] = healthy[:len(victims) // 2]
    down: list[int] = []
    epochs = set()
    for r in range(rounds):
        alive = np.ones(topo.n_nodes, dtype=bool)
        alive[down] = False
        replies = alive & (reply_rng.random(topo.n_nodes) >= truth)
        sch.heartbeat_round(replies)
        if (r + 1) % churn_every == 0 and len(down) < len(victims):
            victim = int(victims[len(down)])
            down.append(victim)
            sch.handle_node_failure([victim])
        rec = sch.submit(Job(wl, distribution="tofa"))
        assert rec.state == "running"
        sch.complete(rec.job.job_id)
        epochs.add(sch.cluster_state().epoch)
    return {"preset": "drain-sweep", "belief_mode": source,
            "fast": fast, "seed": seed, "rounds": rounds,
            "churn_events": len(down), "epochs": len(epochs),
            "events_ingested": (int(tracker.events_ingested)
                                if tracker is not None else 0)}


def tracker_churn_row(fast: bool = False, seed: int = 0,
                      csv=print) -> dict:
    """Gate 3: engine weight-cache hit rate in the tracker serving loop.

    Asserts the tracker's smooth belief drift is fully absorbed by
    ``p_f_atol`` interning — only the genuine failures mint epochs, and
    the hit rate holds the BENCH_state floor.  (The replica presets
    can't measure this: their traces flip genuine health state on
    nearly every placement.)
    """
    engine = PlacementEngine()
    row = _tracker_serving_loop(fast, seed, engine)
    stats = engine.cache_stats()
    row.update({"hit_rate": engine.cache_hit_rate(),
                "weight_hits": stats["weight_hits"],
                "weight_misses": stats["weight_misses"],
                "weight_delta_updates": stats["weight_delta_updates"],
                "min_hit_rate": MIN_TRACKER_HIT_RATE})
    csv(f"beliefs,tracker_churn,hit_rate,{row['hit_rate']:.4f},frac,"
        f"epochs={row['epochs']},churn={row['churn_events']},"
        f"events_ingested={row['events_ingested']},"
        f"floor={MIN_TRACKER_HIT_RATE}")
    return row


def atol_sweep(fast: bool = False, seeds=(0, 1, 2, 3), grid=ATOL_GRID,
               csv=print) -> list[dict]:
    """p_f_atol sensitivity, per belief source, over the serving loop.

    One fresh engine per (source, atol), shared across seeds, so the
    hit rate aggregates the same way the churn gate's does.  Placement
    outcomes are atol-invariant (pattern-only Eq. 1 consumers —
    asserted in ``tests/test_beliefs.py``), so the sensitivity curve is
    hit rate / epoch count vs. tolerance.  The two sources answer two
    questions: ``monitor`` (per-round heartbeat sampling jitter) is the
    regime that sets the scheduler default — 0.15 is the tightest value
    holding the 95% churn floor — while ``learned`` shows the tracker's
    exposure-only drift is smooth enough to stay at the floor at every
    tolerance in the grid.
    """
    rows = []
    for source in ("monitor", "learned"):
        for atol in grid:
            engine = PlacementEngine()
            epochs = churn = 0
            for seed in seeds:
                r = _tracker_serving_loop(fast, seed, engine,
                                          p_f_atol=atol, source=source)
                epochs += r["epochs"]
                churn += r["churn_events"]
            row = {"source": source, "p_f_atol": atol,
                   "hit_rate": engine.cache_hit_rate(),
                   "epochs": epochs, "churn_events": churn,
                   "n_seeds": len(seeds)}
            rows.append(row)
            csv(f"beliefs,atol_sweep,{source}/atol={atol},"
                f"{row['hit_rate']:.4f},hit_rate,"
                f"epochs={epochs},churn={churn}")
    return rows


def run(csv=print, fast: bool | None = None, seed: int = 0) -> dict:
    """benchmarks.run entry: single-seed belief-mode sweep (cheap CSV
    overview; the statistical gate lives behind ``--check``)."""
    if fast is None:
        fast = bool(int(os.environ.get("FAST", "0")))
    out: dict = {}
    for preset in SWEEP_PRESETS:
        out[preset] = {}
        for mode in MODES:
            res = run_preset(preset, policies=("tofa",), seed=seed,
                             fast=fast, belief_mode=mode,
                             checkpointing=False)
            row = res["policies"]["tofa"]
            out[preset][mode] = row
            csv(f"beliefs,{preset},{mode},"
                f"{row['mean_completion']:.4f},s_mean_completion,"
                f"belief_err={row.get('belief_err', float('nan')):.5f}")
    out["tracker_churn"] = tracker_churn_row(fast=fast, seed=seed, csv=csv)
    return out


def check(results: dict, churn: dict) -> int:
    """The CI gate over a :func:`sweep` result + churn row."""
    rc = 0
    beats = []
    for preset, res in results.items():
        cmp = res["comparisons"].get("learned_vs_static")
        if cmp is None:
            continue
        ok = cmp["delta_ci_low"] > 0.0
        beats.append(ok)
        print(f"GATE {preset} learned<static: delta={cmp['delta']:.4f} "
              f"ci=[{cmp['delta_ci_low']:.4f},{cmp['delta_ci_high']:.4f}] "
              f"win_rate={cmp['win_rate']:.3f} "
              f"{'OK' if ok else 'no (needs >=1 preset overall)'}")
    if not any(beats):
        print("GATE learned-beats-static: FAIL "
              "(no preset with delta CI above zero)")
        rc = 1
    for preset, res in results.items():
        rows = res["modes"]
        if "learned" not in rows or "oracle" not in rows:
            continue
        bound = (1.0 + ORACLE_GAP_MAX) * rows["oracle"]["mean_completion"]
        ok = rows["learned"]["mean_completion"] <= bound
        print(f"GATE {preset} oracle-gap: learned="
              f"{rows['learned']['mean_completion']:.4f} <= "
              f"{bound:.4f} (oracle * {1 + ORACLE_GAP_MAX:.2f}) "
              f"{'OK' if ok else 'FAIL'}")
        if not ok:
            rc = 1
    ok = churn["hit_rate"] >= MIN_TRACKER_HIT_RATE
    print(f"GATE tracker-churn: hit_rate={churn['hit_rate']:.4f} >= "
          f"{MIN_TRACKER_HIT_RATE} {'OK' if ok else 'FAIL'}")
    if not ok:
        rc = 1
    return rc


def write_trajectory(point: dict, label: str) -> None:
    doc = {"schema": 1, "trajectory": []}
    if BENCH_PATH.exists():
        doc = json.loads(BENCH_PATH.read_text())
    point = {"label": label, **point}
    doc["trajectory"].append(point)
    BENCH_PATH.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"appended trajectory point {label!r} to {BENCH_PATH}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless learned beats static-prior "
                         "(paired CI > 0 on >= 1 preset), learned lands "
                         "within the oracle gap bound everywhere, and the "
                         "tracker keeps the engine cache hit rate above "
                         "the BENCH_state floor")
    ap.add_argument("--write", action="store_true",
                    help="append a point to BENCH_beliefs.json")
    ap.add_argument("--label", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--replicas", type=int, default=None,
                    help="replicas per (preset, mode); --check defaults "
                         "to 24, --write to 256")
    ap.add_argument("--presets", default=None,
                    help="comma list (default: the sweep presets)")
    ap.add_argument("--modes", default=None,
                    help="comma list (default: oracle,learned,static,"
                         "adversarial)")
    ap.add_argument("--bootstrap", type=int, default=2000)
    ap.add_argument("--alpha", type=float, default=0.05)
    ap.add_argument("--method", default="bca",
                    choices=("percentile", "bca"),
                    help="bootstrap CI flavor for summaries and deltas")
    ap.add_argument("--checkpointed", action="store_true",
                    help="sweep with the presets' default aggressive "
                         "checkpointing instead of restart-from-scratch")
    ap.add_argument("--executor", default="serial",
                    choices=("auto", "serial", "process"))
    ap.add_argument("--workers", "--jobs", dest="workers", type=int,
                    default=None)
    ap.add_argument("--atol-sweep", action="store_true",
                    help="measure the p_f_atol sensitivity grid instead "
                         "of the belief-mode sweep")
    args = ap.parse_args()

    if args.atol_sweep:
        rows = atol_sweep(fast=args.fast)
        if args.write:
            write_trajectory({"fast": args.fast, "atol_sweep": rows},
                             args.label or "atol-sweep")
        return 0

    if args.replicas is None:
        args.replicas = 256 if args.write else 24
    presets = (tuple(p for p in args.presets.split(",") if p)
               if args.presets else SWEEP_PRESETS)
    modes = (tuple(m for m in args.modes.split(",") if m)
             if args.modes else MODES)
    results = sweep(presets, modes, args.replicas, fast=args.fast,
                    base_seed=args.seed, B=args.bootstrap,
                    alpha=args.alpha, method=args.method,
                    checkpointing=args.checkpointed,
                    executor=args.executor, max_workers=args.workers)
    churn = tracker_churn_row(fast=args.fast, seed=args.seed)
    if args.write:
        write_trajectory({
            "fast": args.fast, "checkpointing": args.checkpointed,
            "n_replicas": args.replicas, "method": args.method,
            "presets": results, "tracker_churn": churn},
            args.label or "unlabeled")
    if args.check:
        return check(results, churn)
    return 0


if __name__ == "__main__":
    sys.exit(main())
