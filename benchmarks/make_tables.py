"""Render the EXPERIMENTS.md roofline + perf tables from the dry-run JSONL.

    PYTHONPATH=src python -m benchmarks.make_tables [--root .]
"""
from __future__ import annotations

import argparse
import json
import os


def load(path):
    rows = []
    if os.path.exists(path):
        for line in open(path):
            r = json.loads(line)
            if r.get("ok"):
                rows.append(r)
    return rows


def fmt_ms(s):
    return f"{s*1e3:.1f}"


def roofline_table(rows, mesh="16x16") -> str:
    hdr = ("| arch | shape | compute ms | memory ms | mem(kernel) ms | "
           "collective ms | dominant | useful | roofline | GB/dev | fits | "
           "TOFA hop win |")
    sep = "|" + "---|" * 12
    out = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        plc = r.get("placement", {})
        win = ""
        if "linear" in plc and "tofa" in plc and plc["linear"]["hop_bytes"]:
            w = 1 - plc["tofa"]["hop_bytes"] / plc["linear"]["hop_bytes"]
            win = f"{w:+.1%}"
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_ms(r['compute_s'])} | "
            f"{fmt_ms(r['memory_s'])} | "
            f"{fmt_ms(r.get('memory_s_kernel', r['memory_s']))} | "
            f"{fmt_ms(r['collective_s'])} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.1%} | "
            f"{r['total_bytes_per_dev']/1e9:.1f} | "
            f"{'y' if r['fits_hbm'] else 'n'} | {win} |")
    return "\n".join(out)


def perf_rows(base_rows, perf_rows_, arch, shape, mesh="16x16"):
    sel = [r for r in base_rows
           if r["arch"] == arch and r["shape"] == shape and r["mesh"] == mesh]
    out = [("baseline", sel[0])] if sel else []
    for r in perf_rows_:
        if r["arch"] == arch and r["shape"] == shape and r["mesh"] == mesh:
            out.append((r.get("tag", "variant"), r))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=".")
    args = ap.parse_args()
    base = load(os.path.join(args.root, "experiments_dryrun_final.jsonl"))
    perf = load(os.path.join(args.root, "experiments_perf.jsonl"))
    print("### single-pod 16x16\n")
    print(roofline_table(base, "16x16"))
    print("\n### multi-pod 2x16x16\n")
    print(roofline_table(base, "2x16x16"))
    print("\n### perf variants\n")
    for arch, shape in (("minicpm3-4b", "train_4k"),
                        ("llama-3.2-vision-11b", "decode_32k"),
                        ("phi3.5-moe-42b", "train_4k"),
                        ("nemotron-4-340b", "train_4k")):
        for tag, r in perf_rows(base, perf, arch, shape,
                                mesh="16x16" if arch != "nemotron-4-340b"
                                else "2x16x16"):
            bound = max(r["compute_s"],
                        r.get("memory_s_kernel", r["memory_s"]),
                        r["collective_s"])
            print(f"{arch} x {shape} [{tag}]: "
                  f"compute={fmt_ms(r['compute_s'])}ms "
                  f"mem={fmt_ms(r['memory_s'])}ms "
                  f"mem_kernel={fmt_ms(r.get('memory_s_kernel', 0))}ms "
                  f"coll={fmt_ms(r['collective_s'])}ms "
                  f"GB/dev={r['total_bytes_per_dev']/1e9:.1f} "
                  f"fits={r['fits_hbm']} "
                  f"bound(kernel-adj)={fmt_ms(bound)}ms "
                  f"roofline_adj={(r['model_flops']/197e12)/bound:.1%}")


if __name__ == "__main__":
    main()
