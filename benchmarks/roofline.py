"""E5 — roofline table from the dry-run sweep.

Reads the JSONL written by ``python -m repro.launch.dryrun --all --out
experiments_dryrun.jsonl`` (+ the retry file) and prints the §Roofline
table: per (arch x shape x mesh) the three terms, the dominant one, the
MODEL_FLOPS/HLO_FLOPs ratio, and the TOFA-vs-linear placement win on the
hop-weighted collective term.  Does NOT recompile (the sweep takes ~40 min;
run it via the launcher, not the benchmark harness).
"""
from __future__ import annotations

import json
import os

FILES = ("experiments_dryrun.jsonl", "experiments_dryrun2.jsonl",
         "experiments_dryrun_perf.jsonl")


def load_rows(root: str = ".") -> list[dict]:
    rows: dict = {}
    for f in FILES:
        path = os.path.join(root, f)
        if not os.path.exists(path):
            continue
        for line in open(path):
            r = json.loads(line)
            if r.get("ok"):
                # later files override earlier baselines for the same cell
                rows[(r["arch"], r["shape"], r["mesh"],
                      r.get("moe_impl", ""))] = r
    return list(rows.values())


def run(csv=print, root: str = ".") -> dict:
    rows = load_rows(root)
    if not rows:
        csv("roofline,NO_DATA,run_dryrun_first,0,see_docstring")
        return {}
    out = {}
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        key = f"{r['arch']}|{r['shape']}|{r['mesh']}"
        plc = r.get("placement", {})
        tofa_win = ""
        if "linear" in plc and "tofa" in plc and plc["linear"]["hop_bytes"]:
            win = 1 - plc["tofa"]["hop_bytes"] / plc["linear"]["hop_bytes"]
            tofa_win = f",tofa_hop_win={win:.2%}"
        csv(f"roofline,{key},{r['dominant']},"
            f"{max(r['compute_s'], r['memory_s'], r['collective_s'])*1e3:.1f},"
            f"ms_bound,compute={r['compute_s']*1e3:.1f}ms,"
            f"memory={r['memory_s']*1e3:.1f}ms,"
            f"collective={r['collective_s']*1e3:.1f}ms,"
            f"useful={r['useful_ratio']:.3f},"
            f"roofline_frac={r['roofline_fraction']:.2%},"
            f"fits_hbm={r['fits_hbm']}{tofa_win}")
        out[key] = r
    return out


if __name__ == "__main__":
    run()
