"""E2 / paper Table 1 — torus-arrangement sensitivity (LAMMPS 256).

Paper: Default-Slurm and TOFA timesteps/s vary strongly with the 256-node
torus arrangement (8x8x8, 4x8x16, 8x4x16, 4x4x32, 4x32x4); TOFA is less
sensitive than Default-Slurm, which wins only on the cubic 8x8x8.
"""
from __future__ import annotations

import numpy as np

from repro.core.topology import TorusTopology
from repro.core.engine import PlacementEngine, PlacementRequest
from repro.sim.jobsim import successful_runtime
from repro.sim.network import TorusNetwork
from repro.workloads.patterns import lammps_like

ARRANGEMENTS = [(8, 8, 8), (4, 8, 16), (8, 4, 16), (4, 4, 32), (4, 32, 4)]


def run(csv=print) -> dict:
    wl = lammps_like(256)
    engine = PlacementEngine()
    out = {}
    for dims in ARRANGEMENTS:
        topo = TorusTopology(dims)
        net = TorusNetwork(topo)
        req = PlacementRequest(comm=wl.comm, topology=topo)
        row = {}
        for pol in ("linear", "topo"):
            res = engine.place(req, policy=pol, rng=np.random.default_rng(0))
            t = successful_runtime(wl, res.placement, net)
            row[pol] = 1.0 / t
            name = "x".join(map(str, dims))
            csv(f"table1,{name},{pol},{1.0/t:.3f},steps_per_s")
        out[dims] = row
    # sensitivity = spread of steps/s across arrangements (lower = stabler)
    for pol in ("linear", "topo"):
        vals = np.array([out[d][pol] for d in ARRANGEMENTS])
        sens = float(vals.std() / vals.mean())
        csv(f"table1,sensitivity,{pol},{sens:.3f},cv_across_arrangements")
        out[f"sensitivity_{pol}"] = sens
    return out


if __name__ == "__main__":
    run()
