"""E4 (beyond paper) — mapper cost/quality scaling.

Hop-bytes quality and wall-clock of the Scotch-analogue mapper vs greedy /
random / linear across process counts and torus sizes — establishes that
TOFA placement overhead stays negligible against job runtimes.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.mapping import hop_bytes
from repro.core.topology import TorusTopology
from repro.core.tofa import place
from repro.workloads.patterns import npb_dt_like


def run(csv=print) -> dict:
    out = {}
    for dims, n in [((4, 4, 4), 48), ((8, 8, 8), 85), ((8, 8, 8), 256),
                    ((16, 16), 192), ((8, 8, 8), 410)]:
        topo = TorusTopology(dims)
        D = topo.hop_matrix()
        wl = npb_dt_like(n, seed=3)
        name = "x".join(map(str, dims))
        row = {}
        for pol in ("linear", "random", "greedy", "topo"):
            t0 = time.time()
            res = place(pol, wl.comm, topo, rng=np.random.default_rng(0))
            dt = time.time() - t0
            hb = hop_bytes(wl.comm.G_v, D, res.placement)
            row[pol] = (hb, dt)
            csv(f"mapping_scale,{name}_n{n},{pol},{dt*1e3:.1f},"
                f"ms_place_time,hop_bytes={hb:.3e}")
        out[f"{name}_n{n}"] = row
        rel = row["topo"][0] / row["linear"][0]
        csv(f"mapping_scale,{name}_n{n},topo_vs_linear_hopbytes,"
            f"{rel:.3f},ratio")
    return out


if __name__ == "__main__":
    run()
