"""E4 (beyond paper) — mapper cost/quality scaling + engine cache ablation.

Hop-bytes quality and wall-clock of the Scotch-analogue mapper vs greedy /
random / linear across process counts and torus sizes — establishes that
TOFA placement overhead stays negligible against job runtimes — plus a
cached-vs-uncached comparison of fault-aware placement latency: the
PlacementEngine derives the Eq. 1 route-weight matrix once per
(topology, health) state, so every subsequent placement against the same
health snapshot skips the dominant cost.

``--backend jax`` (or ``run(backend="jax")``) measures the same matrix
under the jitted jax placement backend (``repro.core.backend``) —
placements are identical, so any wall-clock delta is pure backend cost.

Implicit-distance scaling axis (PR 7)::

    ... mapping_scale --implicit            # 16k-, 64k- and 128k-node
        implicit-torus placements, one subprocess per row so peak-RSS is
        per-case; each row also times an incremental ``engine.replace``
        after killing 4 used nodes (the lazy-exact re-placement path)
    ... mapping_scale --implicit --fast     # CI smoke: the 16k-node case
        must finish under a machine-normalised wall budget AND peak RSS
        must stay below the bytes a dense N x N hop matrix alone would
        take (proof the lazy path never densifies); the 128k-node leg
        then runs under the same gates, but only when its predicted wall
        fits IMPLICIT_128K_GUARD_S on this machine
    ... mapping_scale --scale --write       # append a trajectory point to
        benchmarks/BENCH_mapping.json: the refine_scale case matrix plus
        implicit rows carrying additive keys peak_rss_bytes / lazy /
        backend / dense_matrix_bytes / replace_s / replace_provenance

Each implicit row is measured in a subprocess (hidden ``--implicit-case``
mode) because ``ru_maxrss`` is a process-lifetime high-water mark — see
``tools/peak_rss.py``.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

import numpy as np

from repro.core import backend as core_backend
from repro.core.comm_graph import CommGraph
from repro.core.engine import PlacementEngine, PlacementRequest
from repro.core.topology import TorusTopology
from repro.workloads.patterns import npb_dt_like


def run(csv=print, backend: str = "numpy") -> dict:
    with core_backend.use(backend):
        return _run(csv=csv)


def _run(csv=print) -> dict:
    engine = PlacementEngine()
    out = {}
    for dims, n in [((4, 4, 4), 48), ((8, 8, 8), 85), ((8, 8, 8), 256),
                    ((16, 16), 192), ((8, 8, 8), 410)]:
        topo = TorusTopology(dims)
        wl = npb_dt_like(n, seed=3)
        req = PlacementRequest(comm=wl.comm, topology=topo)
        name = "x".join(map(str, dims))
        row = {}
        for pol in ("linear", "random", "greedy", "topo"):
            t0 = time.perf_counter()
            plan = engine.place(req, policy=pol,
                                rng=np.random.default_rng(0))
            dt = time.perf_counter() - t0
            row[pol] = (plan.hop_bytes, dt)
            csv(f"mapping_scale,{name}_n{n},{pol},{dt*1e3:.1f},"
                f"ms_place_time,hop_bytes={plan.hop_bytes:.3e}")
        out[f"{name}_n{n}"] = row
        rel = row["topo"][0] / row["linear"][0]
        csv(f"mapping_scale,{name}_n{n},topo_vs_linear_hopbytes,"
            f"{rel:.3f},ratio")

    out["cache"] = _cache_ablation(csv)
    return out


def _cache_ablation(csv=print, dims=(8, 8, 4), n=85, n_faulty=12,
                    repeats=3) -> dict:
    """Engine-cached vs uncached fault-aware placement latency.

    Uncached = a fresh engine per call (the pre-engine behaviour: every
    call site re-derived hop and Eq. 1 weight matrices).  Cached = one
    engine, matrices derived on the first call only.
    """
    topo = TorusTopology(dims)
    wl = npb_dt_like(n, seed=3)
    p_f = np.zeros(topo.n_nodes)
    p_f[np.random.default_rng(7).choice(topo.n_nodes, n_faulty,
                                        replace=False)] = 0.02
    req = PlacementRequest(comm=wl.comm, topology=topo, p_f=p_f)
    name = "x".join(map(str, dims))

    uncached = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        PlacementEngine().place(req, policy="tofa",
                                rng=np.random.default_rng(0))
        uncached.append(time.perf_counter() - t0)

    engine = PlacementEngine()
    engine.place(req, policy="tofa", rng=np.random.default_rng(0))  # warm
    cached = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        engine.place(req, policy="tofa", rng=np.random.default_rng(0))
        cached.append(time.perf_counter() - t0)

    dt_un, dt_c = float(np.median(uncached)), float(np.median(cached))
    speedup = dt_un / dt_c if dt_c > 0 else float("inf")
    csv(f"mapping_scale,cache_{name}_n{n},tofa_uncached,{dt_un*1e3:.1f},"
        f"ms_place_time")
    csv(f"mapping_scale,cache_{name}_n{n},tofa_cached,{dt_c*1e3:.1f},"
        f"ms_place_time")
    csv(f"mapping_scale,cache_{name}_n{n},cache_speedup,{speedup:.2f},x"
        f"  # hop/weight matrices reused across placements")
    return {"uncached_s": dt_un, "cached_s": dt_c, "speedup": speedup,
            "stats": engine.cache_stats()}


# ---------------------------------------------------------------------------
# Implicit-distance scaling (lazy metric, no dense N x N matrix)

# (case name, torus dims, n_procs, part of --fast smoke)
IMPLICIT_CASES = [
    ("torus-32x32x16/n1024/implicit", (32, 32, 16), 1024, True),
    ("torus-64x32x32/n2048/implicit", (64, 32, 32), 2048, False),
    ("torus-64x64x32/n2048/implicit", (64, 64, 32), 2048, False),
]
# smoke wall-clock budget for the 16k-node case (seconds, on the reference
# machine — scaled by the refine_scale calibration ratio at gate time).
# Measured: numpy warm ~7 s / cold ~8 s; x4 headroom.
IMPLICIT_WALL_BUDGET_S = 30.0
IMPLICIT_CALIBRATION_S = 0.009071  # refine_scale._calibrate() on the
#                                    machine the budget above was measured on
# optional second smoke leg: the 128k-node case runs only when its
# machine-normalised *predicted* wall fits the guard — slow CI runners
# skip the leg instead of timing out on it.
IMPLICIT_128K_CASE = ("torus-64x64x32/n2048/implicit", (64, 64, 32), 2048)
# measured on the reference machine: cold 25.1 s / warm 23.6 s / replace
# 57.1 s (exact Eq. 1 route walks under the 4-failure overlay), 2.0 GB
# peak RSS vs the 137 GB a dense matrix would take
IMPLICIT_128K_EST_S = 110.0       # reference-machine child wall (all phases)
IMPLICIT_128K_GUARD_S = 300.0     # run the leg only if est * scale fits this
IMPLICIT_128K_WALL_BUDGET_S = 95.0    # gate on the measured warm placement
N_REPLACE_FAILED = 4              # nodes killed by the replace micro-bench


def _ring_comm(n: int, w: float = 8.0) -> np.ndarray:
    G = np.zeros((n, n))
    i = np.arange(n)
    G[i, (i + 1) % n] = w
    G[(i + 1) % n, i] = w
    return G


def implicit_case_child(dims: tuple[int, ...], n: int,
                        backend: str = "numpy") -> dict:
    """Measure one implicit-torus placement in *this* process and return
    the row (run via subprocess so peak-RSS is per-case)."""
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from tools.peak_rss import peak_rss_bytes

    topo = TorusTopology(dims)
    comm = CommGraph(n, G_v=_ring_comm(n))
    with core_backend.use(backend):
        engine = PlacementEngine()
        req = PlacementRequest(comm=comm, topology=topo)
        t0 = time.perf_counter()
        plan = engine.place(req, policy="tofa", rng=np.random.default_rng(0))
        cold_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        plan = engine.place(req, policy="tofa", rng=np.random.default_rng(0))
        warm_s = time.perf_counter() - t0
        # fault-driven re-placement micro-bench: kill a handful of *used*
        # nodes and time the incremental move (exercises the lazy-exact
        # replace cost path — blocked row reductions, never a dense D)
        failed = np.random.default_rng(5).choice(
            np.asarray(plan.placement), size=N_REPLACE_FAILED, replace=False)
        t0 = time.perf_counter()
        plan_r = engine.replace(plan, failed_nodes=failed,
                                rng=np.random.default_rng(0))
        replace_s = time.perf_counter() - t0
    from repro.core.lazydist import is_lazy
    lazy = bool(is_lazy(engine.hops(topo)))
    name = f"torus-{'x'.join(map(str, dims))}/n{n}/implicit"
    return {
        "case": name,
        "topology": f"torus-{'x'.join(map(str, dims))}",
        "n_procs": n,
        "n_nodes": topo.n_nodes,
        "n_faulty": 0,
        "policy": "tofa",
        "cold_s": round(cold_s, 6),
        "warm_s": round(warm_s, 6),
        "hop_bytes": float(plan.hop_bytes),
        # additive keys (schema v1-compatible: absent on dense rows)
        "lazy": lazy,
        "backend": backend,
        "peak_rss_bytes": peak_rss_bytes(),
        "dense_matrix_bytes": topo.n_nodes * topo.n_nodes * 8,
        "replace_s": round(replace_s, 6),
        "replace_provenance": plan_r.provenance,
    }


def _measure_implicit(dims: tuple[int, ...], n: int, backend: str,
                      csv=print) -> dict:
    """Run one implicit case in a subprocess and parse its JSON row."""
    repo = Path(__file__).resolve().parents[1]
    cmd = [sys.executable, "-m", "benchmarks.mapping_scale",
           "--implicit-case", "x".join(map(str, dims)), str(n),
           "--backend", backend]
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = str(repo / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    out = subprocess.run(cmd, cwd=repo, env=env, capture_output=True,
                         text=True, check=True)
    row = json.loads(out.stdout.strip().splitlines()[-1])
    csv(f"mapping_scale,{row['case']},implicit,{row['warm_s']*1e3:.0f},"
        f"ms_place_time,cold={row['cold_s']:.2f}s,"
        f"replace={row['replace_s']*1e3:.0f}ms,"
        f"rss={row['peak_rss_bytes']/1e6:.0f}MB,"
        f"dense_would_be={row['dense_matrix_bytes']/1e9:.2f}GB,"
        f"lazy={row['lazy']},backend={row['backend']}")
    return row


def run_implicit(csv=print, backend: str = "numpy",
                 fast: bool = False) -> list[dict]:
    cases = [c for c in IMPLICIT_CASES if c[3]] if fast else IMPLICIT_CASES
    return [_measure_implicit(dims, n, backend, csv=csv)
            for _, dims, n, _ in cases]


def implicit_smoke(csv=print, backend: str = "numpy") -> int:
    """CI gate: the 16k-node implicit placement must stay lazy (peak RSS
    under the dense-matrix bytes alone) and inside the wall budget."""
    from benchmarks import refine_scale

    row = run_implicit(csv=csv, backend=backend, fast=True)[0]
    rc = 0
    if not row["lazy"]:
        csv("mapping_scale,implicit_smoke,FAIL,engine did not go lazy "
            f"(n_nodes={row['n_nodes']})")
        rc = 1
    # machine-speed normalisation, same yardstick as the refine gate
    scale = refine_scale._calibrate() / IMPLICIT_CALIBRATION_S
    scale = min(max(scale, 1.0 / refine_scale.CALIBRATION_CLAMP),
                refine_scale.CALIBRATION_CLAMP)
    limit = IMPLICIT_WALL_BUDGET_S * scale
    csv(f"mapping_scale,implicit_smoke,warm_s,{row['warm_s']:.2f},s,"
        f"machine_scale={scale:.2f},limit={limit:.1f}")
    if row["warm_s"] > limit:
        csv(f"mapping_scale,implicit_smoke,FAIL,warm {row['warm_s']:.1f}s "
            f"> machine-normalised budget {limit:.1f}s")
        rc = 1
    if row["peak_rss_bytes"] >= row["dense_matrix_bytes"]:
        csv(f"mapping_scale,implicit_smoke,FAIL,peak RSS "
            f"{row['peak_rss_bytes']/1e6:.0f}MB >= dense-matrix bytes "
            f"{row['dense_matrix_bytes']/1e6:.0f}MB — lazy path densified?")
        rc = 1
    else:
        csv(f"mapping_scale,implicit_smoke,rss_headroom,"
            f"{row['dense_matrix_bytes']/max(row['peak_rss_bytes'],1):.1f},x,"
            f"dense-matrix bytes / peak RSS")
    # 128k-node leg, behind the wall-budget guard: run it only when the
    # machine-normalised prediction fits — slow runners skip, not time out
    est = IMPLICIT_128K_EST_S * scale
    if est > IMPLICIT_128K_GUARD_S:
        csv(f"mapping_scale,implicit_smoke_128k,SKIP,predicted {est:.0f}s "
            f"> guard {IMPLICIT_128K_GUARD_S:.0f}s on this machine")
    else:
        _, dims, n = IMPLICIT_128K_CASE
        row = _measure_implicit(dims, n, backend, csv=csv)
        limit = IMPLICIT_128K_WALL_BUDGET_S * scale
        if not row["lazy"] or row["peak_rss_bytes"] >= row["dense_matrix_bytes"]:
            csv(f"mapping_scale,implicit_smoke_128k,FAIL,lazy={row['lazy']},"
                f"rss={row['peak_rss_bytes']/1e6:.0f}MB vs dense "
                f"{row['dense_matrix_bytes']/1e9:.0f}GB")
            rc = 1
        elif row["warm_s"] > limit:
            csv(f"mapping_scale,implicit_smoke_128k,FAIL,warm "
                f"{row['warm_s']:.1f}s > machine-normalised budget "
                f"{limit:.1f}s")
            rc = 1
        else:
            csv(f"mapping_scale,implicit_smoke_128k,PASS,"
                f"warm={row['warm_s']:.1f}s,replace={row['replace_s']:.2f}s,"
                f"rss={row['peak_rss_bytes']/1e6:.0f}MB")
    if rc == 0:
        csv("mapping_scale,implicit_smoke,PASS,lazy + within budgets")
    return rc


def scale_trajectory(csv=print, write: bool = False,
                     label: str | None = None,
                     backend: str = "numpy") -> dict:
    """Measure the refine_scale case matrix plus the implicit rows and
    (with ``write``) append one trajectory point to BENCH_mapping.json."""
    from benchmarks import refine_scale

    point = refine_scale.run(csv=csv, write=False, label=label)
    point["cases"].extend(run_implicit(csv=csv, backend=backend))
    if write:
        doc = refine_scale._load_baseline() or {
            "schema": refine_scale.SCHEMA_VERSION,
            "gate": {"case": refine_scale.GATE_CASE,
                     "factor": refine_scale.GATE_FACTOR},
            "trajectory": [],
        }
        doc["trajectory"].append(point)
        with open(refine_scale.BENCH_PATH, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        csv(f"mapping_scale,write,{refine_scale.BENCH_PATH.name},"
            f"trajectory_points={len(doc['trajectory'])}")
    return point


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", choices=("numpy", "jax"), default="numpy")
    ap.add_argument("--implicit", action="store_true",
                    help="measure implicit-distance (lazy) placements at "
                         "16k/64k nodes, one subprocess per row")
    ap.add_argument("--fast", action="store_true",
                    help="with --implicit: CI smoke — gate the 16k-node "
                         "case on wall-clock and peak-RSS budgets")
    ap.add_argument("--scale", action="store_true",
                    help="measure the BENCH_mapping trajectory matrix "
                         "(refine_scale cases + implicit rows)")
    ap.add_argument("--write", action="store_true",
                    help="with --scale: append the point to "
                         "BENCH_mapping.json")
    ap.add_argument("--label", default=None,
                    help="trajectory point label (e.g. the PR name)")
    ap.add_argument("--implicit-case", default=None, metavar="DIMS",
                    help=argparse.SUPPRESS)  # subprocess-only entry
    ap.add_argument("n_procs", nargs="?", type=int, default=None,
                    help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.implicit_case:
        dims = tuple(int(d) for d in args.implicit_case.split("x"))
        row = implicit_case_child(dims, int(args.n_procs or 1024),
                                  backend=args.backend)
        print(json.dumps(row))
        return 0
    if args.implicit:
        if args.fast:
            return implicit_smoke(backend=args.backend)
        run_implicit(backend=args.backend)
        return 0
    if args.scale:
        scale_trajectory(write=args.write, label=args.label,
                         backend=args.backend)
        return 0
    run(backend=args.backend)
    return 0


if __name__ == "__main__":
    sys.exit(main())
