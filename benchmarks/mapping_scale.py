"""E4 (beyond paper) — mapper cost/quality scaling + engine cache ablation.

Hop-bytes quality and wall-clock of the Scotch-analogue mapper vs greedy /
random / linear across process counts and torus sizes — establishes that
TOFA placement overhead stays negligible against job runtimes — plus a
cached-vs-uncached comparison of fault-aware placement latency: the
PlacementEngine derives the Eq. 1 route-weight matrix once per
(topology, health) state, so every subsequent placement against the same
health snapshot skips the dominant cost.

``--backend jax`` (or ``run(backend="jax")``) measures the same matrix
under the jitted jax placement backend (``repro.core.backend``) —
placements are identical, so any wall-clock delta is pure backend cost.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.core import backend as core_backend
from repro.core.engine import PlacementEngine, PlacementRequest
from repro.core.topology import TorusTopology
from repro.workloads.patterns import npb_dt_like


def run(csv=print, backend: str = "numpy") -> dict:
    with core_backend.use(backend):
        return _run(csv=csv)


def _run(csv=print) -> dict:
    engine = PlacementEngine()
    out = {}
    for dims, n in [((4, 4, 4), 48), ((8, 8, 8), 85), ((8, 8, 8), 256),
                    ((16, 16), 192), ((8, 8, 8), 410)]:
        topo = TorusTopology(dims)
        wl = npb_dt_like(n, seed=3)
        req = PlacementRequest(comm=wl.comm, topology=topo)
        name = "x".join(map(str, dims))
        row = {}
        for pol in ("linear", "random", "greedy", "topo"):
            t0 = time.perf_counter()
            plan = engine.place(req, policy=pol,
                                rng=np.random.default_rng(0))
            dt = time.perf_counter() - t0
            row[pol] = (plan.hop_bytes, dt)
            csv(f"mapping_scale,{name}_n{n},{pol},{dt*1e3:.1f},"
                f"ms_place_time,hop_bytes={plan.hop_bytes:.3e}")
        out[f"{name}_n{n}"] = row
        rel = row["topo"][0] / row["linear"][0]
        csv(f"mapping_scale,{name}_n{n},topo_vs_linear_hopbytes,"
            f"{rel:.3f},ratio")

    out["cache"] = _cache_ablation(csv)
    return out


def _cache_ablation(csv=print, dims=(8, 8, 4), n=85, n_faulty=12,
                    repeats=3) -> dict:
    """Engine-cached vs uncached fault-aware placement latency.

    Uncached = a fresh engine per call (the pre-engine behaviour: every
    call site re-derived hop and Eq. 1 weight matrices).  Cached = one
    engine, matrices derived on the first call only.
    """
    topo = TorusTopology(dims)
    wl = npb_dt_like(n, seed=3)
    p_f = np.zeros(topo.n_nodes)
    p_f[np.random.default_rng(7).choice(topo.n_nodes, n_faulty,
                                        replace=False)] = 0.02
    req = PlacementRequest(comm=wl.comm, topology=topo, p_f=p_f)
    name = "x".join(map(str, dims))

    uncached = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        PlacementEngine().place(req, policy="tofa",
                                rng=np.random.default_rng(0))
        uncached.append(time.perf_counter() - t0)

    engine = PlacementEngine()
    engine.place(req, policy="tofa", rng=np.random.default_rng(0))  # warm
    cached = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        engine.place(req, policy="tofa", rng=np.random.default_rng(0))
        cached.append(time.perf_counter() - t0)

    dt_un, dt_c = float(np.median(uncached)), float(np.median(cached))
    speedup = dt_un / dt_c if dt_c > 0 else float("inf")
    csv(f"mapping_scale,cache_{name}_n{n},tofa_uncached,{dt_un*1e3:.1f},"
        f"ms_place_time")
    csv(f"mapping_scale,cache_{name}_n{n},tofa_cached,{dt_c*1e3:.1f},"
        f"ms_place_time")
    csv(f"mapping_scale,cache_{name}_n{n},cache_speedup,{speedup:.2f},x"
        f"  # hop/weight matrices reused across placements")
    return {"uncached_s": dt_un, "cached_s": dt_c, "speedup": speedup,
            "stats": engine.cache_stats()}


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", choices=("numpy", "jax"), default="numpy")
    args = ap.parse_args()
    run(backend=args.backend)
    sys.exit(0)
