"""E7 (beyond paper) — checkpoint/restart + estimator ablation.

The paper assumes no checkpointing; this ablation quantifies how much of
TOFA's advantage survives once checkpoint/restart exists (answer: most of
the *communication* win and part of the *abort* win), and how sensitive the
result is to the heartbeat estimator being imperfect (scheduler sees an
EWMA estimate instead of ground truth).
"""
from __future__ import annotations

import numpy as np

from repro.cluster.failures import BernoulliPerJob
from repro.cluster.heartbeat import EWMA, HeartbeatMonitor
from repro.core.topology import TorusTopology
from repro.sim.batchsim import run_batch
from repro.sim.network import TorusNetwork
from repro.workloads.patterns import npb_dt_like


def run(csv=print) -> dict:
    topo = TorusTopology((8, 8, 8))
    net = TorusNetwork(topo)
    wl = npb_dt_like(85)
    rng_cand = np.random.default_rng(42)
    candidates = rng_cand.choice(512, 16, replace=False)
    fm = BernoulliPerJob(candidates, 0.02)
    truth = fm.outage_vector(512)
    out = {}

    # heartbeat-estimated p_f (imperfect knowledge)
    mon = HeartbeatMonitor(512, EWMA(alpha=0.05))
    mon.simulate_rounds(np.random.default_rng(7), truth, 300)
    est = mon.outage_probabilities()

    scenarios = [
        ("truth_nockpt", truth, None),
        ("est_nockpt", est, None),
        ("truth_ckpt10", truth, 0.1),
        ("blind_nockpt", None, None),
    ]
    base = {}
    for name, known, ck in scenarios:
        for pol in ("linear", "tofa"):
            r = run_batch(
                wl, pol, net, fm, known, n_instances=100,
                rng=np.random.default_rng(1),
                checkpoint_interval=(None if ck is None
                                     else ck * 0.2),  # ~10% of runtime
                checkpoint_overhead=0.002)
            base[(name, pol)] = r
            csv(f"fault_ablation,{name},{pol},{r.completion_time:.2f},"
                f"s_batch,abort_ratio={r.abort_ratio:.3f}")
        imp = 1 - base[(name, 'tofa')].completion_time \
            / base[(name, 'linear')].completion_time
        csv(f"fault_ablation,{name},tofa_improvement,{imp:.3f},frac")
        out[name] = imp
    return out


if __name__ == "__main__":
    run()
