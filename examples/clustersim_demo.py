"""Event-driven cluster simulation — jobs sharing a failing cluster, live.

A 216-node 6x6x6 torus runs a burst of mixed-size MPI-style jobs while
two racks suffer correlated outages with repair: heartbeats feed the
outage estimator, the scheduler queues and backfills, node failures
abort the jobs holding them, ``engine.replace`` moves the displaced
processes and restarts from the latest checkpoint.  Default-slurm
(``linear``) and TOFA placement face the identical failure trace.

    PYTHONPATH=src python examples/clustersim_demo.py
"""
import numpy as np

from repro.cluster.failures import (CompositeProcess, CorrelatedOutages,
                                    ExponentialLifetimes, contiguous_racks)
from repro.cluster.scheduler import Scheduler
from repro.core.engine import PlacementEngine
from repro.core.topology import TorusTopology
from repro.sim.clustersim import ClusterSim, SimConfig
from repro.sim.network import network_for
from repro.sim.scenarios import run_preset
from repro.workloads.arrivals import burst_stream, mixed_size_factory


def main():
    topo = TorusTopology((6, 6, 6))
    net = network_for(topo)
    engine = PlacementEngine()     # shared: matrices derived once

    # two flaky racks: they miss heartbeats AND actually go down
    racks = contiguous_racks(topo.n_nodes, 36)
    flaky_racks, flaky_ids = racks[:2], np.concatenate(racks[:2])
    proc = CompositeProcess([
        CorrelatedOutages(flaky_racks, mtbf=3.0, mttr=0.3),
        ExponentialLifetimes(flaky_ids, mtbf=12.0, mttr=0.5),
    ])

    factory = mixed_size_factory(sizes=(16, 27))
    wls = [factory(np.random.default_rng(100 + i)) for i in range(20)]

    print(f"{topo.n_nodes}-node torus, {len(wls)} jobs at t=0, "
          f"racks 0-1 ({len(flaky_ids)} nodes) flaky\n")
    for pol in ("linear", "tofa"):
        sch = Scheduler(topo, net=net, engine=engine, drain_threshold=0.6)
        truth = np.zeros(topo.n_nodes)
        truth[flaky_ids] = 0.25
        sch.registry.set_outage_probabilities(flaky_ids, 0.25)
        sch.monitor.simulate_rounds(np.random.default_rng(1), truth, 400)

        sim = ClusterSim(
            sch, burst_stream(wls, policy=pol), failure_process=proc,
            config=SimConfig(heartbeat_interval=0.25,
                             checkpoint_interval=0.05,
                             checkpoint_overhead=0.002,
                             restart_delay=0.01,
                             failure_horizon=500.0),
            rng=np.random.default_rng(7))
        res = sim.run()
        print(f"  {pol:6s} mean_completion={res.mean_completion:7.3f}s"
              f"  makespan={res.makespan:7.3f}s"
              f"  queue_wait={res.mean_queue_wait:6.3f}s"
              f"  aborts={res.aborted_attempts:3d}"
              f"  node_failures={res.node_failures}"
              f"  events={res.n_events}")
    print("\npaper protocol through the same event loop "
          "(fast Fig. 4/5 preset):")
    out = run_preset("paper-fig4-5", fast=True, seed=0)
    lin = out["policies"]["linear"]["mean_completion"]
    tofa = out["policies"]["tofa"]["mean_completion"]
    print(f"  linear={lin:.2f}s  tofa={tofa:.2f}s  "
          f"improvement={1 - tofa / lin:.1%} "
          f"(matches batchsim.run_scenario exactly)")


if __name__ == "__main__":
    main()
