"""Quickstart: train a small LM end-to-end with the full stack.

    PYTHONPATH=src python examples/quickstart.py              # CPU-sized
    PYTHONPATH=src python examples/quickstart.py --full       # real 135M

The CPU-sized run trains a reduced smollm-135m (same family/wiring) for a
few hundred steps on the synthetic Markov dataset and prints falling loss.
``--full`` runs the genuine 135M config — sized for real accelerators.
On a pod you would add  --mesh 16x16 --placement tofa  (see
repro/launch/train.py for the production driver and mesh flags).
"""
import subprocess
import sys

if __name__ == "__main__":
    full = "--full" in sys.argv
    args = [sys.executable, "-m", "repro.launch.train",
            "--arch", "smollm-135m",
            "--steps", "300", "--batch", "8", "--seq", "64",
            "--checkpoint-dir", "/tmp/quickstart_ckpt",
            "--checkpoint-every", "100", "--log-every", "25"]
    if not full:
        args.append("--reduced")
    raise SystemExit(subprocess.call(args))
