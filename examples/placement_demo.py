"""TOFA placement on a real compiled JAX program — the paper end to end.

Compiles a small sharded train step on 16 (host-emulated) devices, extracts
its communication graph from the HLO (the paper's profiling tool), prints
the traffic heatmap (Fig. 1 analogue), and compares placement policies on a
4x4 chip fabric with two unhealthy chips (Eq. 1 fault weighting).

    PYTHONPATH=src python examples/placement_demo.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core.engine import PlacementEngine, PlacementRequest  # noqa: E402
from repro.core.placement import Fabric, assign_devices  # noqa: E402
from repro.core.profiler import comm_graph_from_hlo  # noqa: E402
from repro.core.state import ClusterState, NodeHealth  # noqa: E402


def main():
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    D, F, B = 512, 2048, 32

    def step(w1, w2, x):
        h = jnp.einsum("bd,df->bf", x, w1)
        h = jax.nn.relu(h)
        y = jnp.einsum("bf,fd->bd", h, w2)
        return ((y - x) ** 2).mean()

    grad = jax.jit(
        jax.grad(step, argnums=(0, 1)),
        in_shardings=(NamedSharding(mesh, P("data", "model")),
                      NamedSharding(mesh, P("model", "data")),
                      NamedSharding(mesh, P("data", None))))
    with mesh:
        compiled = grad.lower(
            jax.ShapeDtypeStruct((D, F), jnp.float32),
            jax.ShapeDtypeStruct((F, D), jnp.float32),
            jax.ShapeDtypeStruct((B, D), jnp.float32)).compile()

    comm = comm_graph_from_hlo(compiled.as_text(), n_devices=8)
    print("== communication heatmap (8 logical shards) ==")
    print(comm.heatmap(width=8))
    print(f"total traffic: {comm.total_bytes()/1e6:.2f} MB/step\n")

    # physical fabric: a 4x4 ICI torus (16 chips) hosting the 8-shard job;
    # chips 5 and 6 (inside the default linear window!) degraded — the
    # versioned ClusterState is the health input, and its epoch keys the
    # engine caches, so re-running against the same snapshot stays warm
    fabric = Fabric(pod_dims=(4, 4), n_pods=1)
    state = ClusterState.healthy(16).with_outage(
        np.where(np.isin(np.arange(16), [5, 6]), 0.05, 0.0))
    state = state.with_health([5, 6], NodeHealth.DEGRADED)

    print("== placement policies (hop-bytes; chips 5,6 degraded) ==")
    engine = PlacementEngine()
    req = PlacementRequest(comm=comm, topology=fabric, state=state)
    for pol, plan in engine.compare(req).items():
        print(f"  {pol:8s} hop_bytes={plan.hop_bytes/1e6:10.2f}MB "
              f"avg_dilation={plan.avg_dilation:.2f} "
              f"faulty_chips_used={plan.faulty_nodes_used} "
              f"({plan.wall_time_s*1e3:.0f}ms)")

    a = assign_devices(comm, fabric, policy="tofa", state=state,
                       engine=engine)
    print(f"\nTOFA device permutation: {a.permutation.tolist()}")
    print(f"hop-bytes vs linear: {a.improvement:+.1%} "
          f"(faulty chips used: {a.plan.faulty_nodes_used})")


if __name__ == "__main__":
    main()
