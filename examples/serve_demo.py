"""Serving demo: batched decode across architecture families.

Exercises the KV cache (GQA), the compressed-latent cache (MLA), and the
O(1)-in-sequence SSM state cache (mamba2) through the same decode_step API.

    PYTHONPATH=src python examples/serve_demo.py
"""
import subprocess
import sys

ARCHS = ("smollm-135m", "minicpm3-4b", "mamba2-2.7b")

if __name__ == "__main__":
    rc = 0
    for arch in ARCHS:
        print(f"\n=== {arch} (reduced config) ===", flush=True)
        rc |= subprocess.call(
            [sys.executable, "-m", "repro.launch.serve", "--arch", arch,
             "--reduced", "--batch", "2", "--prompt-len", "12",
             "--gen", "8"])
    raise SystemExit(rc)
