"""Fault-tolerant batch scheduling — the paper's Fig. 4/5 experiment, live.

Runs a 512-node 8x8x8 cluster simulation through the PlacementEngine API:
heartbeats infer node health, the scheduler places batches of MPI-style
jobs with default-slurm vs TOFA, failures abort jobs, and the elastic path
*incrementally* re-places a running job when its node dies
(``engine.replace`` moves only the displaced processes).

    PYTHONPATH=src python examples/fault_tolerant_batch.py
"""
import numpy as np

from repro.cluster.failures import BernoulliPerJob
from repro.cluster.heartbeat import EWMA, HeartbeatMonitor
from repro.cluster.scheduler import Job, Scheduler
from repro.core.engine import PlacementEngine
from repro.core.topology import TorusTopology
from repro.sim.batchsim import run_batch
from repro.sim.network import TorusNetwork
from repro.workloads.patterns import lammps_like, npb_dt_like


def main():
    topo = TorusTopology((8, 8, 8))
    net = TorusNetwork(topo)
    engine = PlacementEngine()   # shared: hop/weight matrices derived once
    rng = np.random.default_rng(0)
    candidates = rng.choice(512, 16, replace=False)
    fm = BernoulliPerJob(candidates, p_f=0.02)
    truth = fm.outage_vector(512)

    # 1) heartbeat monitoring converges on the flaky nodes
    mon = HeartbeatMonitor(512, EWMA(alpha=0.05))
    mon.simulate_rounds(np.random.default_rng(1), truth, 400)
    est = mon.outage_probabilities()
    found = set(np.flatnonzero(est > 0.005)) & set(candidates.tolist())
    print(f"heartbeats flagged {len(found)}/16 flaky nodes "
          f"(max est p_f={est.max():.3f})")

    # 2) batches of 100 jobs, default-slurm vs TOFA (paper Fig. 4/5)
    for wl_name, wl in (("NPB-DT-85", npb_dt_like(85)),
                        ("LAMMPS-64", lammps_like(64))):
        rows = {}
        for pol in ("linear", "tofa"):
            r = run_batch(wl, pol, net, fm, est, n_instances=100,
                          rng=np.random.default_rng(2), engine=engine)
            rows[pol] = r
            print(f"  {wl_name:10s} {pol:6s} batch={r.completion_time:7.2f}s"
                  f" abort_ratio={r.abort_ratio:5.1%}"
                  f" run={r.success_runtime:.3f}s")
        imp = 1 - rows["tofa"].completion_time / rows["linear"].completion_time
        print(f"  {wl_name:10s} TOFA improvement: {imp:.1%}"
              f"  (paper: 31% DT / 18.9% LAMMPS)\n")

    # 3) incremental elastic re-placement: a node dies under a running job
    sch = Scheduler(topo, net=net, engine=engine)
    sch.heartbeat_round(np.ones(512, dtype=bool))
    rec = sch.submit(Job(lammps_like(64), distribution="tofa"))
    victim = int(rec.placement.placement[10])
    print(f"job {rec.job.job_id} running on 64 nodes; node {victim} dies...")
    replaced = sch.handle_node_failure([victim])
    plan = rec.placement
    print(f"re-placed {len(replaced)} job(s) via {plan.provenance}; "
          f"restarts={rec.restarts}; "
          f"victim in new placement: "
          f"{victim in set(plan.placement.tolist())}")
    print(f"engine cache: {engine.cache_stats()}")


if __name__ == "__main__":
    main()
